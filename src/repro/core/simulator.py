"""Unified vectorized trace-replay engine.

One policy step is O(K) vector lanes; a trace replays under ``lax.scan``;
independent caches (different traces, seeds, or cache sizes) batch under
``vmap``; fleet-scale studies shard the batch over the device mesh.  This
replaces the paper's libCacheSim + thread-replay setup with a single SPMD
program, and the former ``replay`` / ``replay_batch`` / ``replay_observed``
/ ``replay_sharded`` quartet with one entrypoint::

    result = Engine().replay(policy, requests, K)

``requests`` is a :class:`~repro.core.policy.Request` pytree (or a bare key
array — coerced with unit size/cost) of shape ``[T]`` or ``[B, T]``; pass
``mesh=`` to spread a ``[B, T]`` batch over a device axis, ``observe=True``
to collect per-step policy observables (e.g. DAC's ``k``/``jump``).  Hit,
byte-miss and penalty totals are reduced *inside* the jitted program (per
lane, under vmap/SPMD) — callers read ratios off the result instead of
recomputing them post-hoc from hit masks.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .policy import Policy, Request, StepInfo


class Metrics(NamedTuple):
    """Per-lane replay totals, reduced inside the jitted replay program.
    Byte/cost totals accumulate in float32 (object sizes in bytes overflow
    int32 over long traces)."""

    requests: jax.Array      # int32  — trace length
    hits: jax.Array          # int32
    bytes_total: jax.Array   # float32 — sum of request sizes
    bytes_missed: jax.Array  # float32 — sum of sizes over misses
    cost_total: jax.Array    # float32 — sum of request costs
    penalty: jax.Array       # float32 — sum of costs over misses


class ReplayResult(NamedTuple):
    """Engine output: per-step ``StepInfo`` (leading dims match the input),
    per-lane ``Metrics``, and optional stacked observables."""

    info: StepInfo
    metrics: Metrics
    obs: Any

    # -- conveniences (host-side; float for one lane, ndarray for a batch) --
    @property
    def hits(self):
        return self.info.hit

    @property
    def hit_ratio(self):
        return _ratio(self.metrics.hits, self.metrics.requests)

    @property
    def miss_ratio(self):
        m = self.metrics
        return _ratio(np.asarray(m.requests) - np.asarray(m.hits),
                      m.requests)

    @property
    def byte_miss_ratio(self):
        return _ratio(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def penalty_ratio(self):
        """Cost-weighted miss ratio: sum(cost * miss) / sum(cost)."""
        return _ratio(self.metrics.penalty, self.metrics.cost_total)

    @property
    def total_penalty(self):
        out = np.asarray(self.metrics.penalty, dtype=np.float64)
        return float(out) if out.ndim == 0 else out


def _ratio(num, den):
    num = np.asarray(num, dtype=np.float64)
    den = np.asarray(den, dtype=np.float64)
    out = np.divide(num, den, out=np.zeros_like(num), where=den > 0)
    return float(out) if out.ndim == 0 else out


def _scan_replay(policy: Policy, reqs: Request, K: int,
                 observe: bool) -> ReplayResult:
    state = policy.init(K)
    want_obs = observe and hasattr(policy, "observables")

    def body(st, req):
        st, info = policy.step(st, req)
        obs = policy.observables(st) if want_obs else None
        return st, (info, obs)

    _, (info, obs) = jax.lax.scan(body, state, reqs)
    metrics = Metrics(
        requests=jnp.int32(reqs.key.shape[0]),
        hits=jnp.sum(info.hit, dtype=jnp.int32),
        bytes_total=jnp.sum(reqs.size.astype(jnp.float32)),
        bytes_missed=jnp.sum(info.bytes_missed.astype(jnp.float32)),
        cost_total=jnp.sum(reqs.cost),
        penalty=jnp.sum(info.penalty),
    )
    return ReplayResult(info=info, metrics=metrics, obs=obs)


@partial(jax.jit, static_argnames=("policy", "K", "observe"))
def _replay_single(policy, reqs, K, observe):
    return _scan_replay(policy, reqs, K, observe)


@partial(jax.jit, static_argnames=("policy", "K", "observe"))
def _replay_batched(policy, reqs, K, observe):
    return jax.vmap(lambda r: _scan_replay(policy, r, K, observe))(reqs)


class Engine:
    """The single replay entrypoint: scans one trace, vmaps a ``[B, T]``
    batch, and — given a mesh — shards the batch axis SPMD (each device
    replays B/axis_size independent caches, the TPU-native version of the
    paper's multi-threaded trace replay, Tables IV/V)."""

    def __init__(self, mesh=None, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis

    def replay(self, policy, requests, K: int, *, sizes=None, costs=None,
               mesh=None, axis=None, observe: bool = False) -> ReplayResult:
        """Replay ``requests`` through ``policy`` at capacity ``K``.

        ``policy`` may be a :class:`Policy` instance or a spec string for
        :func:`repro.core.make_policy` (e.g. ``"dac(eps=0.5)"``).
        ``requests``: a :class:`Request`, or bare keys (``sizes``/``costs``
        then broadcast per :meth:`Request.of`).
        """
        if isinstance(policy, str):
            from . import make_policy
            policy = make_policy(policy)
        reqs = Request.of(requests, sizes, costs)
        if reqs.key.ndim == 1:
            return _replay_single(policy, reqs, K, observe)
        if reqs.key.ndim != 2:
            raise ValueError(
                f"requests must be [T] or [B, T], got shape {reqs.key.shape}")
        mesh = self.mesh if mesh is None else mesh
        if mesh is not None:
            sharding = NamedSharding(mesh, P(axis or self.axis, None))
            reqs = jax.device_put(reqs, sharding)
        return _replay_batched(policy, reqs, K, observe)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def miss_ratio(hits) -> float:
    return float(1.0 - np.asarray(hits, dtype=np.float64).mean())


def mrr(mr_algo: float, mr_fifo: float) -> float:
    """Miss-ratio reduction relative to FIFO (paper's signed definition).
    Both-zero is explicitly no-reduction (0.0) rather than falling through
    either signed branch."""
    if mr_algo == 0.0 and mr_fifo == 0.0:
        return 0.0
    if mr_algo <= mr_fifo:
        return (mr_fifo - mr_algo) / mr_fifo if mr_fifo > 0 else 0.0
    return (mr_fifo - mr_algo) / mr_algo if mr_algo > 0 else 0.0
