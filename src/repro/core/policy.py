"""Policy interface + shared vectorized primitives.

The paper formulates its caches as ordered lists (rank 1 = top).  The
TPU-native representation used throughout this repo is a dense ``int32[K]``
array of keys ordered by rank (index 0 = top of the cache); ``EMPTY`` (-1)
marks unused slots.  The paper's "shift elements between a and b down one
position" becomes a masked select against a rolled copy of the array — an
O(K) *vector* operation that lowers to a handful of VPU selects instead of a
data-dependent pointer splice.

Every policy is a pure-functional object::

    state = policy.init(K)                  # pytree of fixed-shape arrays
    state, hit = policy.step(state, key)    # key: int32 scalar, hit: bool

``step`` is traceable (scan/vmap/jit safe).  Policy instances are hashable
(static) so ``jax.jit(..., static_argnames='policy')`` works.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)


class Policy:
    """Base class; subclasses implement init/step. Instances are static."""

    name: str = "base"

    def init(self, K: int) -> dict:
        raise NotImplementedError

    def step(self, state: dict, key: jax.Array):
        raise NotImplementedError

    # hashability for jit static args -----------------------------------
    def _fields(self):
        return tuple(sorted(self.__dict__.items()))

    def __hash__(self):
        return hash((type(self).__name__, self._fields()))

    def __eq__(self, other):
        return type(self) is type(other) and self._fields() == other._fields()

    def __repr__(self):
        args = ", ".join(f"{k}={v}" for k, v in self.__dict__.items())
        return f"{type(self).__name__}({args})"


# ---------------------------------------------------------------------------
# shared vectorized primitives
# ---------------------------------------------------------------------------

def find(cache: jax.Array, key: jax.Array):
    """Return (found, rank) of `key` in the rank-ordered `cache` array."""
    eq = cache == key
    return jnp.any(eq), jnp.argmax(eq).astype(jnp.int32)


def promote(cache: jax.Array, i: jax.Array, t: jax.Array, key: jax.Array):
    """Move `key` (currently at rank ``i``) to rank ``t`` (t <= i), shifting
    ranks [t, i-1] down one.  Also implements miss-insertion when ``i`` is the
    eviction rank (the old occupant of rank ``i`` simply disappears)."""
    r = jnp.arange(cache.shape[0], dtype=jnp.int32)
    rolled = jnp.roll(cache, 1)  # rolled[r] = cache[r-1]
    return jnp.where(r == t, key, jnp.where((r > t) & (r <= i), rolled, cache))


def demote(cache: jax.Array, i: jax.Array, t: jax.Array, key: jax.Array):
    """Move `key` from rank ``i`` down to rank ``t`` (t >= i); [i+1, t] shift up."""
    r = jnp.arange(cache.shape[0], dtype=jnp.int32)
    rolled = jnp.roll(cache, -1)  # rolled[r] = cache[r+1]
    return jnp.where(r == t, key, jnp.where((r >= i) & (r < t), rolled, cache))
