"""Policy interface + shared vectorized primitives.

The paper formulates its caches as ordered lists (rank 1 = top).  The
TPU-native representation used throughout this repo is a dense ``int32[W]``
array of keys ordered by rank (index 0 = top of the cache); ``EMPTY`` (-1)
marks unused slots.  The paper's "shift elements between a and b down one
position" becomes a masked select against a rolled copy of the array — an
O(W) *vector* operation that lowers to a handful of VPU selects instead of a
data-dependent pointer splice.

Rank rows are **lane-padded**: the array width ``W = lane_pad(K)`` is the
logical capacity ``K`` rounded up to a multiple of :data:`LANE` (128, the
TPU vector-lane count), with the padding filled with ``EMPTY``.  The
logical length rides alongside as a control *scalar* (``len`` for
fixed-size policies, ``k``/``kmax`` for DynamicAdaptiveClimb), never as an
array shape — so the same state pytree batches under ``vmap``, resizes
under Alg. 2, and tiles cleanly through the compiled Pallas kernel.  The
padding invariants every rank policy maintains:

  * ranks ``>= k`` (the active length) are ``EMPTY`` after every step —
    in particular the padding ``[K, W)`` never holds a key;
  * ``find``/``promote``/``demote``/``rank_step`` are equivalent on the
    padded row and the tight row: the roll wrap value is never selected
    (``t <= src`` keeps rank 0 out of the shifted range) and a wipe only
    ever clears already-``EMPTY`` padding ranks.

Every policy is a pure-functional object::

    state = policy.init(K)                      # pytree of fixed-shape arrays
    state, info = policy.step(state, request)   # Request -> StepInfo

``Request`` carries ``(key, size, cost)`` so size-aware (byte miss ratio)
and cost-aware (miss penalty) objectives flow through the engine natively;
``size``/``cost`` default to 1/1.0, so plain key traces reproduce the
classic unit-object model bit-for-bit.  ``StepInfo`` reports, per request,
the hit bit, the key that left residency this step (``EMPTY`` if none), and
the size/cost charged on a miss.

``step`` is traceable (scan/vmap/jit safe).  Policy instances are hashable
(static) so ``jax.jit(..., static_argnames='policy')`` works.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = jnp.int32(-1)  # repolint: waive[empty-sentinel] -- the definition

# TPU vector-lane count: rank rows are padded to a multiple of LANE so the
# fused policy-step kernel can tile them through VMEM with Mosaic-legal
# (…, 128k) blocks.  The jnp lowering runs on the same padded rows — state
# shapes are identical across lowerings, so parity tests compare pytrees
# directly and switching `use_pallas` never retraces a different program.
LANE = 128


def lane_pad(n: int) -> int:
    """Padded rank-row width for logical capacity ``n``: the smallest
    multiple of :data:`LANE` that holds ``n`` (at least one full lane).

    >>> lane_pad(1), lane_pad(128), lane_pad(129), lane_pad(1000)
    (128, 128, 256, 1024)
    """
    if n < 0:
        raise ValueError(f"capacity must be non-negative, got {n}")
    return max(LANE, -(-int(n) // LANE) * LANE)


def padded_row(n: int) -> jax.Array:
    """A fresh all-``EMPTY`` rank row of padded width ``lane_pad(n)``.

    >>> row = padded_row(5)
    >>> row.shape, int(row[0])
    ((128,), -1)
    """
    return jnp.full((lane_pad(n),), EMPTY, dtype=jnp.int32)


class Request(NamedTuple):
    """One cache request: object key + size (bytes/pages/slots) + miss cost
    (latency, backend load, ...).  A pytree, so a ``Request`` of ``[T]`` (or
    ``[B, T]``) arrays scans/vmaps exactly like a bare key trace."""

    key: jax.Array    # int32
    size: jax.Array   # int32
    cost: jax.Array   # float32

    @classmethod
    def of(cls, keys, sizes=None, costs=None) -> "Request":
        """Build a ``Request`` from keys, broadcasting ``sizes``/``costs``
        (scalars or per-key arrays; default 1 / 1.0).

        >>> r = Request.of([3, 1, 3], sizes=4096)
        >>> r.key.shape, int(r.size[0]), float(r.cost[0])
        ((3,), 4096, 1.0)
        """
        if isinstance(keys, Request):
            if sizes is not None or costs is not None:
                raise ValueError("pass sizes/costs inside the Request")
            return keys
        key = jnp.asarray(keys, jnp.int32)
        # sizes are int32 on device; reject concrete values that would
        # silently wrap (an object >= 2 GiB corrupts every byte-miss
        # metric).  Tracers can't be inspected — they stay caller-checked.
        if sizes is not None and not isinstance(sizes, jax.core.Tracer):
            smax = np.max(np.asarray(sizes)) if np.size(sizes) else 0
            if smax > np.iinfo(np.int32).max:
                raise ValueError(
                    f"sizes exceed int32 range (max {smax}); rescale to "
                    "coarser units (KiB/pages) before building Requests")
        size = jnp.broadcast_to(
            jnp.asarray(1 if sizes is None else sizes, jnp.int32), key.shape)
        cost = jnp.broadcast_to(
            jnp.asarray(1.0 if costs is None else costs, jnp.float32),
            key.shape)
        return cls(key=key, size=size, cost=cost)


class StepInfo(NamedTuple):
    """Per-request policy output (a pytree; scan stacks it along time)."""

    hit: jax.Array           # bool
    evicted_key: jax.Array   # int32; EMPTY when nothing left residency
    bytes_missed: jax.Array  # int32; == request size on miss, else 0
    penalty: jax.Array       # float32; == request cost on miss, else 0


def step_info(hit, req: Request, evicted_key=EMPTY) -> StepInfo:
    """Assemble a ``StepInfo``: evictions only happen on misses, and a miss
    charges the request's full size and cost.

    >>> info = step_info(False, Request.of(jnp.int32(7), sizes=100))
    >>> int(info.bytes_missed), float(info.penalty)
    (100, 1.0)
    >>> int(step_info(True, Request.of(jnp.int32(7), sizes=100)).bytes_missed)
    0
    """
    hit = jnp.asarray(hit, jnp.bool_)
    return StepInfo(
        hit=hit,
        evicted_key=jnp.where(hit, EMPTY,
                              jnp.asarray(evicted_key, jnp.int32)),
        bytes_missed=jnp.where(hit, jnp.int32(0), req.size),
        penalty=jnp.where(hit, jnp.float32(0.0), req.cost),
    )


class Policy:
    """Base class for all replacement policies; subclasses implement
    ``init(K) -> state`` and ``step(state, req) -> (state, StepInfo)``.
    Instances are static: hashable and comparable by constructor fields,
    so they work as ``jax.jit`` static arguments.

    >>> from repro.core import make_policy
    >>> make_policy("lru") == make_policy("lru")
    True
    >>> make_policy("dac(eps=0.25)") == make_policy("dac")
    False
    """

    name: str = "base"

    def init(self, K: int) -> dict:
        raise NotImplementedError

    def step(self, state: dict, req: Request):
        raise NotImplementedError

    # hashability for jit static args -----------------------------------
    def _fields(self):
        return tuple(sorted(self.__dict__.items()))

    def __hash__(self):
        return hash((type(self).__name__, self._fields()))

    def __eq__(self, other):
        return type(self) is type(other) and self._fields() == other._fields()

    def __repr__(self):
        args = ", ".join(f"{k}={v}" for k, v in self.__dict__.items())
        return f"{type(self).__name__}({args})"


# ---------------------------------------------------------------------------
# shared vectorized primitives
# ---------------------------------------------------------------------------

def find(cache: jax.Array, key: jax.Array):
    """Return (found, rank) of `key` in the rank-ordered `cache` array.

    >>> hit, i = find(jnp.array([5, 3, 9], jnp.int32), jnp.int32(3))
    >>> bool(hit), int(i)
    (True, 1)
    """
    eq = cache == key
    return jnp.any(eq), jnp.argmax(eq).astype(jnp.int32)


def promote(cache: jax.Array, i: jax.Array, t: jax.Array, key: jax.Array):
    """Move `key` (currently at rank ``i``) to rank ``t`` (t <= i), shifting
    ranks [t, i-1] down one.  Also implements miss-insertion when ``i`` is the
    eviction rank (the old occupant of rank ``i`` simply disappears).

    >>> promote(jnp.array([5, 3, 9], jnp.int32), 2, 0, 9).tolist()
    [9, 5, 3]
    """
    r = jnp.arange(cache.shape[0], dtype=jnp.int32)
    rolled = jnp.roll(cache, 1)  # rolled[r] = cache[r-1]
    return jnp.where(r == t, key, jnp.where((r > t) & (r <= i), rolled, cache))


def demote(cache: jax.Array, i: jax.Array, t: jax.Array, key: jax.Array):
    """Move `key` from rank ``i`` down to rank ``t`` (t >= i); [i+1, t] shift up.

    >>> demote(jnp.array([5, 3, 9], jnp.int32), 0, 2, 5).tolist()
    [3, 9, 5]
    """
    r = jnp.arange(cache.shape[0], dtype=jnp.int32)
    rolled = jnp.roll(cache, -1)  # rolled[r] = cache[r+1]
    return jnp.where(r == t, key, jnp.where((r >= i) & (r < t), rolled, cache))


# ---------------------------------------------------------------------------
# fused rank step: find + plan + promote in one pass
# ---------------------------------------------------------------------------

_PALLAS_STEP = contextvars.ContextVar("repro_use_pallas_step", default=False)

# the three-valued use_pallas knob threaded through every replay entrypoint:
#   False       — pure-jnp lowering (find + promote as separate jnp ops)
#   "interpret" — fused Pallas kernel under the Pallas interpreter (any
#                 backend; the CPU CI path)
#   "compiled"  — fused Pallas kernel compiled for real (Mosaic on TPU,
#                 Triton on GPU); fails on CPU, which cannot execute
#                 compiled Pallas
#   True        — auto: the kernel with per-backend interpret resolution
#                 (see repro.kernels.policy_step.resolve_interpret)
PALLAS_MODES = (False, True, "interpret", "compiled")


def normalize_pallas_mode(mode):
    """Coerce a ``use_pallas`` value to one of :data:`PALLAS_MODES`.

    >>> normalize_pallas_mode(1), normalize_pallas_mode("interpret")
    (True, 'interpret')
    >>> normalize_pallas_mode("fast")
    Traceback (most recent call last):
        ...
    ValueError: use_pallas must be one of (False, True, 'interpret', \
'compiled'), got 'fast'
    """
    if isinstance(mode, str):
        if mode not in ("interpret", "compiled"):
            raise ValueError(
                f"use_pallas must be one of {PALLAS_MODES}, got {mode!r}")
        return mode
    return bool(mode)


@contextlib.contextmanager
def pallas_mode(mode):
    """Trace-time switch: inside this context, :func:`rank_step` lowers to
    the fused Pallas kernel (``repro.kernels.policy_step``) instead of the
    pure-jnp ``find``/``promote`` pair.  ``mode`` is any of
    :data:`PALLAS_MODES` — ``False`` (jnp), ``"interpret"``, ``"compiled"``,
    or ``True`` (kernel with per-backend interpret resolution).

    Engine-internal: the Engine sets it around tracing and threads the mode
    through its jit static args so all lowerings coexist in the cache.
    Wrapping an already-jitted function in this context does NOT retrace it
    — use ``Engine(use_pallas=...)`` / ``replay(..., use_pallas=...)``,
    which is the supported switch."""
    tok = _PALLAS_STEP.set(normalize_pallas_mode(mode))
    try:
        yield
    finally:
        _PALLAS_STEP.reset(tok)


def rank_step(cache: jax.Array, key: jax.Array, scalars: tuple, plan):
    """One fused step of a rank-array policy.

    ``plan(hit, i, scalars) -> (src, t, wipe_from, new_scalars)`` is the
    policy's O(1) control law: given the find result it picks the shift
    source rank ``src`` (the eviction rank on a miss), the insertion rank
    ``t`` (``t <= src``), a deactivation boundary ``wipe_from`` (ranks >=
    ``wipe_from`` are cleared to ``EMPTY``; pass ``K`` for none), and the
    updated control scalars (int32 each).

    Returns ``(new_cache, new_scalars, hit, evicted)``; ``evicted`` is the
    pre-update occupant of rank ``src`` — callers mask it with
    :func:`step_info` (hits never evict).

    This is the single entrypoint behind which ``find`` + ``promote`` fuse:
    under :func:`pallas_mode` the whole step — compare, iota-min reduce,
    scalar plan, rolled masked-select shift, wipe — is one tiled Pallas
    kernel (``repro.kernels.policy_step``): the row streams HBM→VMEM in
    :data:`LANE`-multiple tiles, so K no longer has to fit one VMEM row.
    ``"interpret"`` runs the same kernel body under the Pallas interpreter
    (the CPU fallback); ``"compiled"`` lowers it for real (Mosaic/Triton).
    Rows of non-padded width are padded with ``EMPTY`` for the kernel and
    sliced back — bit-identical to the jnp lowering either way.

    A CLIMB-shaped plan (miss replaces the bottom rank in place):

    >>> def plan(hit, i, scalars):
    ...     src = jnp.where(hit, i, jnp.int32(2))
    ...     t = jnp.where(hit, jnp.maximum(i - 1, 0), jnp.int32(2))
    ...     return src, t, jnp.int32(3), ()
    >>> cache = jnp.array([5, 3, 9], jnp.int32)
    >>> new, _, hit, ev = rank_step(cache, jnp.int32(7), (), plan)
    >>> new.tolist(), bool(hit), int(ev)
    ([5, 3, 7], False, 9)
    """
    mode = _PALLAS_STEP.get()
    if mode:
        from ..kernels.policy_step import fused_policy_step
        interpret = {True: None, "interpret": True, "compiled": False}[mode]
        return fused_policy_step(cache, key, scalars, plan,
                                 interpret=interpret)
    hit, i = find(cache, key)
    src, t, wipe_from, new_scalars = plan(hit, i, scalars)
    evicted = cache[src]
    new_cache = promote(cache, src, t, key)
    r = jnp.arange(cache.shape[0], dtype=jnp.int32)
    new_cache = jnp.where(r >= wipe_from, EMPTY, new_cache)
    return new_cache, new_scalars, hit, evicted
