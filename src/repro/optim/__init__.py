from .adamw import AdamWConfig, dequantize, global_norm, init, quantize, \
    schedule, update

__all__ = ["AdamWConfig", "init", "update", "schedule", "global_norm",
           "quantize", "dequantize"]
