"""AdamW with cosine schedule, global-norm clipping, and optional 8-bit
block-quantized moments (for the >=100B archs, Adam m/v at f32 dominates
HBM: 8 bytes/param -> 2 bytes/param + 1/64 block scales).

Pure-functional: ``init(params) -> state``, ``update(grads, state, params)
-> (params, state, stats)``.  The moment quantization is symmetric blockwise
(block 64 along the flattened last axis) with f32 scales — the standard
bnb-style scheme, exact enough that smoke-training loss curves match f32
moments to ~1e-3.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 64


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # float32 | int8


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


# --- blockwise int8 moment quantization ------------------------------------

def _pad_len(n):
    return -(-n // BLOCK) * BLOCK


def quantize(x, sqrt_domain: bool = False):
    """f32 array -> {'q': int8, 'scale': f32[blocks]} (flat blocks).

    sqrt_domain=True quantizes sqrt(x) (x >= 0) — used for the second
    moment, whose *quadratic* dynamic range otherwise rounds small-|g|
    elements to v=0 while their m survives, exploding m/(sqrt(v)+eps)."""
    flat = x.reshape(-1)
    if sqrt_domain:
        flat = jnp.sqrt(jnp.maximum(flat, 0.0))
    pad = _pad_len(flat.size) - flat.size
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    q = jnp.round(flat / jnp.maximum(scale[:, None], 1e-20))
    return {"q": q.astype(jnp.int8), "scale": scale}


def dequantize(qd, shape, sqrt_domain: bool = False):
    flat = qd["q"].astype(jnp.float32) * qd["scale"][:, None]
    if sqrt_domain:
        flat = jnp.square(flat)
    return flat.reshape(-1)[: math.prod(shape)].reshape(shape)


def _wrap_moment(x, dtype, sqrt_domain=False):
    return quantize(x, sqrt_domain) if dtype == "int8" else x


def _unwrap_moment(m, shape, dtype, sqrt_domain=False):
    return dequantize(m, shape, sqrt_domain) if dtype == "int8" else m


# --- optimizer --------------------------------------------------------------

def init(params, cfg: AdamWConfig):
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _wrap_moment(z, cfg.moment_dtype)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else 1.0

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _unwrap_moment(m, p.shape, cfg.moment_dtype)
        v_f = _unwrap_moment(v, p.shape, cfg.moment_dtype, sqrt_domain=True)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, _wrap_moment(m_f, cfg.moment_dtype), \
            _wrap_moment(v_f, cfg.moment_dtype, sqrt_domain=True)

    is_q = cfg.moment_dtype == "int8"

    def is_leaf(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_leaf)[0] if is_q \
        else treedef.flatten_up_to(state["m"])
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_leaf)[0] if is_q \
        else treedef.flatten_up_to(state["v"])

    out = [leaf(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
