"""Shared spec-string machinery for the registries.

Both registries — policies (``repro.core.make_policy``) and traces
(``repro.data.traces.make_trace``) — speak the same tiny language::

    name
    name(k1=v1, k2=v2, ...)

This module owns the parser and the type-coercion rules so the two stay in
lockstep: values are coerced to the *declared* type of the target callable's
parameter (inferred from its default, falling back to its annotation), an
integer knob rejects non-integral floats, and an unknown parameter raises
``ValueError`` naming the accepted ones.
"""
from __future__ import annotations

import inspect
import re

__all__ = ["parse_spec", "coerce_value", "build_kwargs", "format_spec",
           "split_top"]

_SPEC_RE = re.compile(r"([a-z0-9_]+)\s*(?:\((.*)\))?\s*", re.I | re.S)

# annotations arrive as strings under `from __future__ import annotations`
_ANNOT_TYPES = {"int": int, "float": float, "bool": bool, "str": str}


def parse_spec(spec: str) -> tuple[str, str | None]:
    """Split ``"name(args)"`` into ``(name, argstr)``; ``argstr`` is ``None``
    when no parenthesis group is present."""
    m = _SPEC_RE.fullmatch(spec.strip())
    if not m:
        raise ValueError(f"unparseable spec {spec!r}")
    return m.group(1).lower(), m.group(2)


def split_top(argstr: str | None) -> list:
    """Split a spec argument string on *top-level* commas only — commas
    inside nested parentheses stay put, so composite specs such as
    ``admit(dac(eps=0.5,growth=4),filter=tinylfu)`` keep their base-policy
    spec intact.  Empty segments are dropped; ``None`` splits to ``[]``.

    >>> split_top("dac(eps=0.5,growth=4),filter=tinylfu")
    ['dac(eps=0.5,growth=4)', 'filter=tinylfu']
    >>> split_top("a=1,b=2"), split_top(None), split_top("  ")
    (['a=1', 'b=2'], [], [])
    """
    if argstr is None:
        return []
    parts, depth, cur = [], 0, []
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise ValueError(f"unbalanced parentheses in {argstr!r}")
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced parentheses in {argstr!r}")
    parts.append("".join(cur))
    return [p for p in (q.strip() for q in parts) if p]


def _coerce_literal(text: str):
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text.strip("'\"")


def _declared_type(param: inspect.Parameter):
    """The type a spec value must land as: the default's type when one is
    declared, else the (string or real) annotation."""
    if param.default is not inspect.Parameter.empty:
        return type(param.default)
    ann = param.annotation
    if isinstance(ann, str):
        return _ANNOT_TYPES.get(ann)
    return ann if isinstance(ann, type) else None


def coerce_value(kind: str, name: str, params: dict, key: str, value):
    """Coerce a parsed spec value to the declared type of parameter ``key``
    of registry entry ``name`` (``params`` = its ``inspect`` parameters),
    so ``growth=4.0`` and ``growth=4`` build identical objects instead of
    one smuggling a float through an integer knob."""
    param = params.get(key)
    if param is None:
        raise ValueError(
            f"unknown parameter {key!r} for {kind} {name!r}; accepts: "
            f"{sorted(params)}")
    target = _declared_type(param)
    if target is None or isinstance(value, str):
        return value
    if target is bool:
        if not isinstance(value, bool):
            raise ValueError(
                f"{name}({key}=...) expects a bool, got {value!r}")
        return value
    if target is int:
        if isinstance(value, float):
            if not value.is_integer():
                raise ValueError(
                    f"{name}({key}=...) expects an integer, got {value!r}")
            return int(value)
        return int(value)
    if target is float:
        return float(value)
    return value


def build_kwargs(kind: str, name: str, fn, argstr: str | None, *,
                 skip: tuple[str, ...] = ("self",)) -> dict:
    """Parse ``argstr`` ("k1=v1,k2=v2") into kwargs coerced against ``fn``'s
    signature; parameters in ``skip`` are not spec-settable."""
    params = {k: p for k, p in inspect.signature(fn).parameters.items()
              if k not in skip}
    kwargs = {}
    if argstr and argstr.strip():
        for part in split_top(argstr):
            k, sep, v = part.partition("=")
            if not sep:
                raise ValueError(
                    f"{kind} spec args must be k=v, got {part!r}")
            k = k.strip()
            kwargs[k] = coerce_value(kind, name, params, k,
                                     _coerce_literal(v.strip()))
    return kwargs


def format_spec(name: str, kwargs: dict) -> str:
    """Canonical string form: ``name`` or ``name(k=v,...)`` (insertion
    order preserved)."""
    if not kwargs:
        return name
    args = ",".join(f"{k}={v}" for k, v in kwargs.items())
    return f"{name}({args})"
